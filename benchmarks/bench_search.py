"""Exp-1 (Fig. 5): search efficiency — CubeGraph vs PostFiltering / ACORN /
PreFiltering / TreeGraph, box filters, recall@20 vs QPS across filter ratios."""
from __future__ import annotations

import numpy as np

from repro.core import CubeGraphConfig, CubeGraphIndex
from repro.core.baselines import (AcornIndex, PostFilteringIndex,
                                  PreFilteringIndex, TreeGraphIndex)
from repro.core.workloads import (ground_truth, make_box_filter, make_dataset)

from .common import BENCH_D, BENCH_N, BENCH_Q, csv_row, curve, record

EFS = (16, 32, 64, 128)
RATIOS = (0.01, 0.05, 0.10)
K = 20


def run():
    x, s = make_dataset(BENCH_N, BENCH_D, 2, seed=1)
    rng = np.random.default_rng(2)
    q = x[rng.integers(0, BENCH_N, BENCH_Q)] \
        + 0.05 * rng.normal(size=(BENCH_Q, BENCH_D)).astype(np.float32)

    cg = CubeGraphIndex.build(x, s, CubeGraphConfig(n_layers=5, m_intra=16,
                                                    m_cross=4))
    post = PostFilteringIndex(x, s, m_intra=16)
    pre = PreFilteringIndex(x, s, m_intra=16)
    acorn = AcornIndex(x, s, m_intra=16, gamma=12)
    tree = TreeGraphIndex(x, s, leaf_size=max(BENCH_N // 32, 128), m_intra=16)

    out = {}
    for ratio in RATIOS:
        f = make_box_filter(2, ratio, seed=int(ratio * 1000))
        gt, _ = ground_truth(x, s, q, f, K)
        res = {}
        res["cubegraph"] = curve(
            lambda ef: cg.query(q, f, k=K, ef=ef)[0], EFS, q, gt, K)
        res["postfilter"] = curve(
            lambda ef: post.query(q, f, k=K, ef=ef)[0], EFS, q, gt, K)
        res["prefilter"] = curve(
            lambda ef: pre.query(q, f, k=K, ef=ef)[0], EFS, q, gt, K)
        res["acorn"] = curve(
            lambda ef: acorn.query(q, f, k=K, ef=ef)[0], EFS, q, gt, K)
        res["treegraph"] = curve(
            lambda ef: tree.query(q, f, k=K, ef=ef)[0], EFS, q, gt, K)
        out[f"ratio_{ratio}"] = res
        for name, cu in res.items():
            best = max(cu, key=lambda r: r["recall"])
            csv_row(f"exp1/{name}/r{ratio}", best["us_per_query"],
                    f"recall={best['recall']};qps={best['qps']}")
    record("exp1_search_efficiency", out)
    return out


if __name__ == "__main__":
    run()
