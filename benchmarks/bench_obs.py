"""Exp-14: observed per-bucket statistics + tracer overhead.

Runs the exp-12 workload shape (one jumbo sealed segment plus a stream of
small seals, ``n_shards=2``) against temporally windowed queries so
whole-block pruning actually fires, then reports

* the per-capacity-bucket observation stats the cost-based planner will
  consume (pruning rate, censored filter selectivity, scanned padded rows,
  dispatch-cache hit rate) straight from ``SegmentManager.stats()["obs"]``;
* the steady-state query latency with tracing **off** (the production
  configuration) and with a full span-tree trace attached, and their ratio
  — the tracer must cost < 2% on the median untraced latency, since the
  span clocks only wrap dispatches that already block on device results.

The top-level payload keys ``pruning_rate`` / ``selectivity`` /
``tracer_overhead_pct`` feed the BENCH_streaming.json perf-trajectory
digest (see ``common.streaming_summary``).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import CubeGraphConfig, IntervalFilter
from repro.streaming import SegmentManager, StreamConfig

from .common import BENCH_D, BENCH_N, BENCH_Q, csv_row, record

CFG = CubeGraphConfig(n_layers=3, m_intra=12, m_cross=4)
REPS = 15


def _latency_samples_us(fn, reps=REPS):
    """Per-rep wall times of ``fn()`` in µs over ``reps`` calls (after the
    caller has warmed compilation)."""
    lats = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        lats.append((time.perf_counter() - t0) * 1e6)
    return lats


def _median(lats):
    lats = sorted(lats)
    return lats[len(lats) // 2]


def run():
    d = BENCH_D
    jumbo = max(BENCH_N // 2, 2048)
    small = max(BENCH_N // 24, 256)
    n_small = 8
    rng = np.random.default_rng(41)
    q = rng.normal(size=(BENCH_Q, d)).astype(np.float32)

    def batch(gen, n, t0):
        x = gen.normal(size=(n, d)).astype(np.float32)
        s = gen.uniform(size=(n, 3))
        s[:, 2] = t0 + np.linspace(0.0, 0.9, n)
        return x, s

    gen = np.random.default_rng(41)
    mgr = SegmentManager(d, 3, StreamConfig(
        time_dim=2, seal_max_points=1 << 30, n_shards=2, index_cfg=CFG))
    x, s = batch(gen, jumbo, 0.0)
    mgr.ingest(x, s)
    mgr.seal()
    for i in range(n_small):
        x, s = batch(gen, small, float(i + 1))
        mgr.ingest(x, s)
        mgr.seal()

    # a mid-stream window: covers the first few small segments but prunes
    # the jumbo segment and the tail — pruning + selectivity both non-trivial
    filt = IntervalFilter(dim=2, lo=np.float32(1.2), hi=np.float32(3.8))
    mgr.query(q, filt, k=10)                      # build pack + compile
    mgr.query(q, None, k=10)                      # compile unfiltered too

    untraced_lats = _latency_samples_us(lambda: mgr.query(q, filt, k=10))
    untraced_us = _median(untraced_lats)
    traced_us = _median(_latency_samples_us(
        lambda: mgr.query(q, filt, k=10, return_trace=True)))
    overhead_pct = (traced_us - untraced_us) / untraced_us * 100.0

    obs = mgr.stats()["obs"]
    buckets = obs["buckets"]
    total = {k: sum(row[k] for row in buckets.values())
             for k in ("rows", "blocks_pruned", "candidates",
                       "candidate_slots", "dispatches", "cache_hits")}
    pruning_rate = round(total["blocks_pruned"] / max(total["rows"], 1), 4)
    selectivity = round(total["candidates"]
                        / max(total["candidate_slots"], 1), 4)
    cache_hit_rate = round(total["cache_hits"]
                           / max(total["dispatches"], 1), 4)

    # one fully traced query for the span-tree exhibit
    _, _, trace = mgr.query(q, filt, k=10, return_trace=True)

    out = {
        "jumbo_points": jumbo, "small_points": small,
        "n_small_segments": n_small, "reps": REPS,
        "us_per_query": round(untraced_us / BENCH_Q, 1),
        # every untraced rep, so the digest's median_query_us is a real
        # median over REPS samples rather than a single value
        "latency_samples": [{"us_per_query": round(us / BENCH_Q, 1)}
                            for us in untraced_lats],
        "traced_us_per_query": round(traced_us / BENCH_Q, 1),
        "tracer_overhead_pct": round(overhead_pct, 2),
        "pruning_rate": pruning_rate,
        "selectivity": selectivity,
        "dispatch_cache_hit_rate": cache_hit_rate,
        "query_ms_hist": obs["metrics"]["histograms"]["query_ms"],
        # raw per-bucket counts only: the derived rates are dropped from
        # the embedded copy so the BENCH_streaming.json digest picks up
        # exactly one pruning_rate/selectivity per section (the aggregate)
        "buckets": {cap: {k: v for k, v in row.items()
                          if k not in ("pruning_rate", "selectivity")}
                    for cap, row in buckets.items()},
        "trace": trace.to_dict(),
    }
    csv_row("exp14/observed_stats", out["us_per_query"],
            f"pruning_rate={pruning_rate};selectivity={selectivity};"
            f"tracer_overhead_pct={out['tracer_overhead_pct']};"
            f"cache_hit_rate={cache_hit_rate}")
    record("exp14_observed_stats", out)
    return out


if __name__ == "__main__":
    run()
