"""Benchmark driver: one section per paper table/figure.
Prints ``name,us_per_call,derived`` CSV and writes
experiments/bench_results.json."""
from __future__ import annotations

import sys
import time


def main() -> None:
    from . import (bench_aspect_ratio, bench_distributions,
                   bench_filter_shapes, bench_index_cost, bench_kernels,
                   bench_merge_count, bench_merge_strategy, bench_multidim,
                   bench_persistence, bench_scalability, bench_search,
                   bench_streaming, bench_updates)
    from .common import flush_results

    sections = [
        ("exp1_search_efficiency", bench_search.run),
        ("exp2_multidim", bench_multidim.run),
        ("exp3_filter_shapes", bench_filter_shapes.run),
        ("exp4_index_cost", bench_index_cost.run),
        ("exp5_dynamic_updates", bench_updates.run),
        ("exp6_merge_count", bench_merge_count.run),
        ("exp7_scalability", bench_scalability.run),
        ("exp8_distributions", bench_distributions.run),
        ("exp9_streaming", bench_streaming.run),
        ("exp10_sharded_mesh", bench_streaming.run_sharded),
        ("exp11_persistence", bench_persistence.run),
        ("a5_aspect_ratio", bench_aspect_ratio.run),
        ("a6_merge_strategy", bench_merge_strategy.run),
        ("kernels", bench_kernels.run),
    ]
    only = sys.argv[1] if len(sys.argv) > 1 else None
    print("name,us_per_call,derived")
    for name, fn in sections:
        if only and only not in name:
            continue
        t0 = time.time()
        try:
            fn()
        except Exception as e:  # noqa: BLE001 — keep the suite going
            print(f"{name},0,ERROR={type(e).__name__}:{e}")
        print(f"# section {name} took {time.time()-t0:.1f}s", flush=True)
    path = flush_results()
    print(f"# results written to {path}")


if __name__ == "__main__":
    main()
