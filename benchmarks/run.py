"""Benchmark driver: one section per paper table/figure.
Prints ``name,us_per_call,derived`` CSV, writes
experiments/bench_results.json, and distills the streaming sections into
the top-level BENCH_streaming.json perf-trajectory summary.

Section registration is declarative (:data:`SECTIONS`) and *loud*: every
module is imported individually through :func:`load_sections`, so one
module that raises on import no longer silently removes every other
section from the run (the old single grouped ``from . import (...)``
failure mode) — import/entry-point failures are reported per section and
the driver exits non-zero.  ``tests/test_planner.py`` smoke-checks that
every registered module imports and exposes its entry point.
"""
from __future__ import annotations

import importlib
import json
import os
import sys
import time

STREAMING_SUMMARY_PATH = os.path.join(os.path.dirname(__file__), "..",
                                      "BENCH_streaming.json")

# (section name, module under benchmarks/, entry-point attribute) — the
# single source of truth for what the driver runs, in run order.
SECTIONS = (
    ("exp1_search_efficiency", "bench_search", "run"),
    ("exp2_multidim", "bench_multidim", "run"),
    ("exp3_filter_shapes", "bench_filter_shapes", "run"),
    ("exp4_index_cost", "bench_index_cost", "run"),
    ("exp5_dynamic_updates", "bench_updates", "run"),
    ("exp6_merge_count", "bench_merge_count", "run"),
    ("exp7_scalability", "bench_scalability", "run"),
    ("exp8_distributions", "bench_distributions", "run"),
    ("exp9_streaming", "bench_streaming", "run"),
    ("exp10_sharded_mesh", "bench_streaming", "run_sharded"),
    ("exp11_persistence", "bench_persistence", "run"),
    ("exp12_pack_maintenance", "bench_streaming", "run_pack_maintenance"),
    ("exp13_quantized_scan", "bench_quant", "run"),
    ("exp14_observed_stats", "bench_obs", "run"),
    ("exp15_read_path_planner", "bench_planner", "run"),
    ("exp16_tiered_storage", "bench_tiering", "run"),
    ("exp17_resilience", "bench_resilience", "run"),
    ("exp18_serving", "bench_serving", "run"),
    ("a5_aspect_ratio", "bench_aspect_ratio", "run"),
    ("a6_merge_strategy", "bench_merge_strategy", "run"),
    ("kernels", "bench_kernels", "run"),
)


def load_sections():
    """Import every registered module and resolve its entry point.

    Returns ``(loaded, errors)`` where ``loaded`` is ``[(name, fn), ...]``
    in registration order and ``errors`` is ``[(name, exc), ...]`` for
    sections whose module failed to import or lacks the attribute —
    each failure costs only its own section, never the whole run.
    """
    loaded, errors = [], []
    for name, mod_name, attr in SECTIONS:
        try:
            mod = importlib.import_module(f".{mod_name}",
                                          package=__package__)
            loaded.append((name, getattr(mod, attr)))
        except Exception as e:  # noqa: BLE001 — reported + non-zero exit
            errors.append((name, e))
    return loaded, errors


def flush_streaming_summary(results_path: str) -> str:
    """Re-derive ``BENCH_streaming.json`` (median latency + pack bytes per
    streaming experiment) from the merged results file, so the summary
    always reflects every recorded section — including ones not re-run in
    this invocation."""
    from .common import streaming_summary
    with open(results_path) as f:
        results = json.load(f)
    summary = {
        "source": "experiments/bench_results.json",
        "generated_by": "benchmarks/run.py",
        "sections": streaming_summary(results),
    }
    with open(STREAMING_SUMMARY_PATH, "w") as f:
        json.dump(summary, f, indent=1)
        f.write("\n")
    return STREAMING_SUMMARY_PATH


def main() -> None:
    from .common import flush_results

    sections, errors = load_sections()
    for name, e in errors:
        print(f"# SECTION LOAD FAILED {name}: {type(e).__name__}: {e}",
              file=sys.stderr, flush=True)
    only = sys.argv[1] if len(sys.argv) > 1 else None
    failed = [name for name, _ in errors if not only or only in name]
    print("name,us_per_call,derived")
    for name, fn in sections:
        if only and only not in name:
            continue
        t0 = time.time()
        try:
            fn()
        except Exception as e:  # noqa: BLE001 — keep the suite going
            print(f"{name},0,ERROR={type(e).__name__}:{e}")
            failed.append(name)
        print(f"# section {name} took {time.time()-t0:.1f}s", flush=True)
    path = flush_results()
    print(f"# results written to {path}")
    print(f"# streaming summary written to {flush_streaming_summary(path)}")
    if failed:
        print(f"# FAILED sections: {', '.join(failed)}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
