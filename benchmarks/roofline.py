"""§Roofline table generator: aggregates experiments/dryrun/*.json into the
per-(arch x shape x mesh) roofline table for EXPERIMENTS.md.

  PYTHONPATH=src python -m benchmarks.roofline [--mesh pod1] [--markdown]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def load_cells(mesh: str = ""):
    cells = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        rec = json.load(open(path))
        if mesh and rec.get("mesh") != mesh:
            continue
        cells.append(rec)
    return cells


def fmt_row(rec):
    arch, shape, mesh = rec["arch"], rec["shape"], rec["mesh"]
    if rec["status"] == "skipped":
        return [arch, shape, mesh, "SKIP", "-", "-", "-", "-", "-", "-",
                rec.get("reason", "")[:48]]
    if rec["status"] != "ok":
        return [arch, shape, mesh, "ERR", "-", "-", "-", "-", "-", "-",
                rec.get("error", "")[:48]]
    ro = rec.get("roofline", {})
    m = rec["full"]["memory"]
    note = ""
    if rec.get("accum"):
        note = f"accum={rec['accum'][-1]['accum']}"
    dom = ro.get("bottleneck", "?")
    terms = [ro.get("compute_s", 0), ro.get("memory_s", 0),
             ro.get("collective_s", 0)]
    frac = (ro.get("compute_s", 0) / max(max(terms), 1e-12))
    return [arch, shape, mesh, "ok",
            f"{ro.get('compute_s', 0):.3f}", f"{ro.get('memory_s', 0):.3f}",
            f"{ro.get('collective_s', 0):.3f}", dom,
            f"{frac:.2f}", f"{ro.get('useful_ratio', 0):.2f}",
            f"peak={m['peak_per_device_bytes']/1e9:.1f}GB "
            f"fits={'Y' if m['fits_hbm'] else 'N'} {note}"]


HEADER = ["arch", "shape", "mesh", "status", "compute_s", "memory_s",
          "collective_s", "bottleneck", "roofline_frac", "useful_ratio",
          "memory/notes"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="")
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args()
    cells = load_cells(args.mesh)
    rows = [fmt_row(r) for r in cells]
    if args.markdown:
        print("| " + " | ".join(HEADER) + " |")
        print("|" + "---|" * len(HEADER))
        for r in rows:
            print("| " + " | ".join(str(c) for c in r) + " |")
    else:
        print(",".join(HEADER))
        for r in rows:
            print(",".join(str(c) for c in r))
    ok = sum(1 for r in cells if r["status"] == "ok")
    skip = sum(1 for r in cells if r["status"] == "skipped")
    err = len(cells) - ok - skip
    print(f"# {len(cells)} cells: {ok} ok, {skip} skipped, {err} error")


if __name__ == "__main__":
    main()
