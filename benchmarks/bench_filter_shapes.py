"""Exp-3 (Fig. 7): complex filter shapes — box vs polygon-3/4/5 vs radius vs
composed (box-minus-circle)."""
from __future__ import annotations

import numpy as np

from repro.core import CubeGraphConfig, CubeGraphIndex
from repro.core.workloads import (ground_truth, make_ball_filter,
                                  make_box_filter, make_compose_filter,
                                  make_dataset, make_polygon_filter)

from .common import BENCH_D, BENCH_N, BENCH_Q, csv_row, curve, record

EFS = (32, 64, 128)
K = 20


def run():
    x, s = make_dataset(BENCH_N, BENCH_D, 2, seed=5)
    rng = np.random.default_rng(6)
    q = x[rng.integers(0, BENCH_N, BENCH_Q)] \
        + 0.05 * rng.normal(size=(BENCH_Q, BENCH_D)).astype(np.float32)
    idx = CubeGraphIndex.build(x, s, CubeGraphConfig(n_layers=5, m_intra=16,
                                                     m_cross=4))
    shapes = {
        "box": lambda r, sd: make_box_filter(2, r, seed=sd),
        "polygon3": lambda r, sd: make_polygon_filter(2, r, 3, seed=sd),
        "polygon4": lambda r, sd: make_polygon_filter(2, r, 4, seed=sd),
        "polygon5": lambda r, sd: make_polygon_filter(2, r, 5, seed=sd),
        "radius": lambda r, sd: make_ball_filter(2, r, seed=sd),
        "compose": lambda r, sd: make_compose_filter(2, r, seed=sd),
    }
    out = {}
    for ratio in (0.05, 0.10):
        for name, mk in shapes.items():
            f = mk(ratio, int(ratio * 100) + 7)
            gt, _ = ground_truth(x, s, q, f, K)
            cu = curve(lambda ef: idx.query(q, f, k=K, ef=ef)[0],
                       EFS, q, gt, K)
            out[f"{name}_r{ratio}"] = cu
            best = max(cu, key=lambda r_: r_["recall"])
            csv_row(f"exp3/{name}/r{ratio}", best["us_per_query"],
                    f"recall={best['recall']};qps={best['qps']}")
    record("exp3_filter_shapes", out)
    return out


if __name__ == "__main__":
    run()
