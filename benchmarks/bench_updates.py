"""Exp-5 (Fig. 8): incremental update vs rebuild-from-scratch."""
from __future__ import annotations

import time

import numpy as np

from repro.core import CubeGraphConfig, CubeGraphIndex
from repro.core.workloads import (ground_truth, make_box_filter, make_dataset,
                                  recall)

from .common import BENCH_D, BENCH_N, BENCH_Q, csv_row, record

CFG = CubeGraphConfig(n_layers=4, m_intra=12, m_cross=4)


def run():
    n = max(BENCH_N // 2, 4000)
    x, s = make_dataset(n + n // 2, BENCH_D, 2, seed=9)
    rng = np.random.default_rng(10)
    q = x[rng.integers(0, n, BENCH_Q)] \
        + 0.05 * rng.normal(size=(BENCH_Q, BENCH_D)).astype(np.float32)
    f = make_box_filter(2, 0.05, seed=11)
    out = {}
    for frac in (0.1, 0.3, 0.5):
        n_add = int(n * frac)
        base = CubeGraphIndex.build(x[:n], s[:n], CFG)
        t0 = time.perf_counter()
        base.insert_batch(x[n:n + n_add], s[n:n + n_add])
        t_inc = time.perf_counter() - t0
        t0 = time.perf_counter()
        rebuilt = CubeGraphIndex.build(x[:n + n_add], s[:n + n_add], CFG)
        t_full = time.perf_counter() - t0
        gt, _ = ground_truth(x[:n + n_add], s[:n + n_add], q, f, 10)
        r_inc = recall(base.query(q, f, k=10, ef=96)[0], gt)
        r_full = recall(rebuilt.query(q, f, k=10, ef=96)[0], gt)
        out[f"frac_{frac}"] = {
            "incremental_s": round(t_inc, 2), "rebuild_s": round(t_full, 2),
            "speedup": round(t_full / max(t_inc, 1e-9), 2),
            "recall_incremental": round(r_inc, 4),
            "recall_rebuild": round(r_full, 4)}
        csv_row(f"exp5/update_{int(frac*100)}pct", t_inc * 1e6,
                f"speedup={out[f'frac_{frac}']['speedup']}x;"
                f"recall={r_inc:.3f}")
    record("exp5_dynamic_updates", out)
    return out


if __name__ == "__main__":
    run()
