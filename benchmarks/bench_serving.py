"""exp18 — multi-tenant serving tier under geo-temporal traffic.

Runs the :mod:`repro.serving.workload` harness (moving time windows,
Zipf-skewed hot regions, ingest bursts mid-query, per-request SLOs) over
a shared :class:`~repro.serving.tenancy.MultiTenantStore` and reports,
per the PR-10 acceptance contract:

* recall@10 of non-degraded answers vs a numpy brute-force per-tenant
  oracle (the exact scan path must hold >= 0.95 — asserted),
* p50/p99 request latency plus SLO-violation / degraded / rejected
  fractions,
* the bit-for-bit tenant-isolation check (shared-substrate answers ==
  dedicated single-tenant oracle stores — asserted),
* ``latency_samples`` rows (one ``us_per_query`` per measured flush) so
  the ``BENCH_streaming.json`` digest medians a real sample set.

A second mini-section exercises the heterogeneous-batch parity claim
directly: a mixed-tenant mixed-filter service flush must equal solo
``MultiTenantStore.retrieve`` calls bit-for-bit (also asserted — this is
the continuous-filtered-batching correctness contract, not a trend).
"""
from __future__ import annotations

import numpy as np

from .common import csv_row, record


def _hetero_parity_check() -> dict:
    """Mixed-tenant mixed-filter flush vs solo retrieves, bit-for-bit."""
    from repro.core import BallFilter, BoxFilter
    from repro.core.cubegraph import CubeGraphConfig
    from repro.serving.rag import Document
    from repro.serving.service import CubeGraphService, ServeRequest
    from repro.serving.tenancy import MultiTenantStore
    from repro.streaming import StreamConfig

    rng = np.random.default_rng(3)
    d, m = 16, 3
    store = MultiTenantStore(
        d, m, stream_cfg=StreamConfig(
            time_dim=2, seal_max_points=96, n_shards=2,
            index_cfg=CubeGraphConfig(n_layers=2, m_intra=8, m_cross=4)))
    svc = CubeGraphService(store)
    for tenant, base in (("a", 0), ("b", 10_000)):
        store.create_collection(tenant)
        docs = [Document(doc_id=base + i,
                         tokens=np.arange(4, dtype=np.int32),
                         embedding=rng.standard_normal(d)
                         .astype(np.float32),
                         metadata=np.array([rng.uniform(0, 10),
                                            rng.uniform(0, 10),
                                            float(i)]))
                for i in range(250)]
        store.insert(tenant, docs)
    store.maintenance()
    filters = (BoxFilter(lo=np.float32([0, 0, -1e9]),
                         hi=np.float32([8, 8, 1e9])),
               BallFilter(center=np.float32([5, 5]),
                          radius=np.float32(3.5)),
               None)
    reqs = []
    for rid in range(12):
        reqs.append(ServeRequest(
            req_id=rid, tenant=("a", "b")[rid % 2],
            query_emb=rng.standard_normal(d).astype(np.float32),
            filt=filters[rid % 3], k=(5, 10)[rid % 2]))
    for r in reqs:
        assert svc.submit(r) is None
    answers = svc.flush()
    n_ok = 0
    for r in reqs:
        sr = answers[r.req_id]
        solo = store.retrieve(r.tenant, r.query_emb, r.filt, k=r.k)
        assert np.array_equal(sr.gids, solo.gids[0]) \
            and np.array_equal(sr.dists, solo.dists[0]) \
            and [d.doc_id for d in sr.docs] == \
                [d.doc_id for d in solo.docs[0]], \
            f"hetero-batch parity violated for req {r.req_id}"
        n_ok += 1
    return {"n_requests": len(reqs), "n_parity_ok": n_ok}


def run() -> None:
    """Entry point registered as ``exp18_serving`` in benchmarks/run.py."""
    from repro.serving.workload import (GeoTemporalWorkload,
                                        SLO_REPORT_KEYS, WorkloadConfig)

    report = GeoTemporalWorkload(WorkloadConfig(
        n_tenants=2, n_initial=400, n_steps=6, queries_per_step=10,
        burst_points=64, warmup_steps=2, seal_max_points=128,
        n_shards=2, deadline_ms=2000.0, slo_ms=2000.0)).run()
    missing = [key for key in SLO_REPORT_KEYS if key not in report]
    assert not missing, f"SLO report missing keys: {missing}"
    assert report["isolation_ok"], "tenant isolation check failed"
    assert report["recall_at_10"] is not None \
        and report["recall_at_10"] >= 0.95, \
        f"recall@10 {report['recall_at_10']} below the 0.95 floor"
    parity = _hetero_parity_check()
    record("exp18_serving", {"workload": report,
                             "hetero_batch_parity": parity})
    samples = [row["us_per_query"] for row in report["latency_samples"]]
    csv_row("exp18_serving",
            float(np.median(samples)) if samples else 0.0,
            f"recall@10={report['recall_at_10']} "
            f"p50={report['latency_ms_p50']}ms "
            f"p99={report['latency_ms_p99']}ms "
            f"slo_viol={report['slo_violation_fraction']} "
            f"degraded={report['degraded_fraction']} "
            f"isolation_ok={report['isolation_ok']}")


if __name__ == "__main__":
    run()
