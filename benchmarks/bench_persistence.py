"""Exp-11: durable streaming snapshots — snapshot/restore latency vs
segment count, plus restored-replica query parity.

Measures, per segment count:
  * cold ``snapshot_to`` (every segment artifact written) and warm
    re-snapshot (artifacts reused, only state + manifest rewritten)
  * ``SegmentManager.restore`` wall time (manifest + mmapped artifacts +
    WAL-tail replay) — the replica warm-start cost
  * first-query latency on the restored manager vs the live one, and a
    bit-for-bit parity check on the results (the persistence acceptance
    property, here measured rather than asserted)
"""
from __future__ import annotations

import os
import shutil
import tempfile
import time

import numpy as np

from repro.core import CubeGraphConfig
from repro.streaming import SegmentManager, StreamConfig

from .common import BENCH_D, BENCH_N, BENCH_Q, csv_row, record

CFG = CubeGraphConfig(n_layers=3, m_intra=12, m_cross=4)


def _build_manager(n: int, n_segments: int) -> SegmentManager:
    x, s = (np.random.default_rng(61).normal(
        size=(n, BENCH_D)).astype(np.float32),
        np.random.default_rng(62).uniform(size=(n, 3)))
    s[:, 2] = np.arange(n) / n
    mgr = SegmentManager(BENCH_D, 3, StreamConfig(
        time_dim=2, seal_max_points=max(n // n_segments, 64),
        compact_max_segments=4 * n_segments, index_cfg=CFG))
    mgr.ingest(x, s)
    return mgr


def run():
    """Benchmark snapshot/restore across segment counts (exp11)."""
    n = max(BENCH_N // 2, 2000)
    rng = np.random.default_rng(63)
    q = rng.normal(size=(BENCH_Q, BENCH_D)).astype(np.float32)
    out = {"n_points": n, "rows": []}
    for n_segments in (2, 4, 8, 16):
        mgr = _build_manager(n, n_segments)
        root = tempfile.mkdtemp(prefix="cg-bench-persist-")
        try:
            t0 = time.perf_counter()
            mgr.snapshot_to(root)
            cold_s = time.perf_counter() - t0
            mgr.delete(rng.integers(0, n, size=n // 50))
            t0 = time.perf_counter()
            mgr.snapshot_to(root)             # artifacts reused
            warm_s = time.perf_counter() - t0
            t0 = time.perf_counter()
            restored = SegmentManager.restore(root, resume=False)
            restore_s = time.perf_counter() - t0
            t0 = time.perf_counter()
            g_r, d_r = restored.query(q, None, k=10, ef=64)
            first_query_s = time.perf_counter() - t0
            g_l, d_l = mgr.query(q, None, k=10, ef=64)
            snapshot_bytes = sum(
                os.path.getsize(os.path.join(dirpath, f))
                for dirpath, _, files in os.walk(root) for f in files)
            row = {
                "n_segments": len(mgr.segments),
                "cold_snapshot_ms": round(cold_s * 1e3, 2),
                "warm_snapshot_ms": round(warm_s * 1e3, 2),
                "restore_ms": round(restore_s * 1e3, 2),
                "restored_first_query_ms": round(first_query_s * 1e3, 2),
                "snapshot_MB": round(snapshot_bytes / 1e6, 2),
                "bit_identical": bool(np.array_equal(g_l, g_r)
                                      and np.array_equal(d_l, d_r)),
            }
            out["rows"].append(row)
            csv_row(f"exp11/segments_{row['n_segments']}",
                    restore_s * 1e6,
                    f"cold_ms={row['cold_snapshot_ms']};"
                    f"warm_ms={row['warm_snapshot_ms']};"
                    f"identical={row['bit_identical']}")
        finally:
            shutil.rmtree(root, ignore_errors=True)
    record("exp11_persistence", out)
