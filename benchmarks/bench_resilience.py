"""Exp-17: resilience — degraded-mode quality and fault-free overhead
(``streaming/resilience.py``).

Three measurements over an era'd multi-bucket corpus:

  * **fault-free overhead** — median query latency with the full
    resilience substrate active (supervisor-owned workers, a disarmed
    ``FaultInjector`` threaded through every fault point) vs. a plain
    manager.  The substrate on the hot path is one ``is None`` check per
    fault point and a ``QueryResult`` wrap, so the acceptance bound is
    < 2% (measured on min-of-samples, the noise-robust estimator).
  * **degraded mode under cold-tier stalls** — ``delays=`` injection
    stalls every per-bucket dispatch while a per-query deadline is set:
    reports the degraded-query fraction and the recall of the partial
    answers against the fault-free oracle (partial answers are real
    answers from the buckets that made the deadline — never garbage).
  * **compaction crash/retry** — an injected crash at
    ``compaction.execute``: the supervisor retries, health counters
    record the error, and post-compaction answers stay bit-for-bit.
"""
from __future__ import annotations

import statistics
import time

import numpy as np

from repro.core import CubeGraphConfig, IntervalFilter
from repro.core.workloads import recall
from repro.streaming import FaultInjector, SegmentManager, StreamConfig

from .common import BENCH_D, BENCH_Q, csv_row, record

CFG = CubeGraphConfig(n_layers=2, m_intra=8, m_cross=4)

# Era'd stream (same rationale as exp16): per-era segment sizes land in
# distinct capacity buckets, so a stalled per-bucket dispatch loop has
# several buckets to time out between.
_ERAS = ((6, 500), (3, 1000), (2, 2000))          # (segments, points)


def _mgr():
    return SegmentManager(BENCH_D, 3, StreamConfig(
        time_dim=2, seal_max_points=1 << 30, n_shards=2, index_cfg=CFG))


def _workload(seed=67):
    rng = np.random.default_rng(seed)
    n = sum(k * sz for k, sz in _ERAS)
    x = rng.normal(size=(n, BENCH_D)).astype(np.float32)
    s = rng.uniform(size=(n, 3))
    s[:, 2] = np.linspace(0.0, 8.0, n)
    q = x[rng.integers(0, n, BENCH_Q)] \
        + 0.05 * rng.normal(size=(BENCH_Q, BENCH_D)).astype(np.float32)
    return x, s, q


def _ingest_eras(mgr, x, s):
    lo = 0
    for n_segs, size in _ERAS:
        for _ in range(n_segs):
            mgr.ingest(x[lo:lo + size], s[lo:lo + size])
            mgr.seal()
            lo += size


def run():
    x, s, q = _workload()
    f = IntervalFilter(2, 0.0, 8.0)

    plain = _mgr()
    _ingest_eras(plain, x, s)
    g_ref, _ = plain.query(q, f, k=10)

    armed = _mgr()
    _ingest_eras(armed, x, s)
    inj = FaultInjector()
    inj.disarm()                     # counts hits, never fires: the
    armed.install_fault_injector(inj)  # fault-free production shape
    g_a, _ = armed.query(q, f, k=10)
    assert np.array_equal(g_ref, g_a)

    # Interleave the two managers' reps so clock/scheduler drift during
    # the measurement hits both sides equally — two back-to-back blocks
    # put all the drift on one ratio leg and flake the 2% gate.
    plain_fn = lambda: plain.query(q, f, k=10)[0]   # noqa: E731
    armed_fn = lambda: armed.query(q, f, k=10)[0]   # noqa: E731
    plain_fn(), armed_fn()                          # warmup (jit compile)
    plain_lats, armed_lats = [], []
    for _ in range(21):
        t0 = time.perf_counter()
        plain_fn()
        plain_lats.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        armed_fn()
        armed_lats.append(time.perf_counter() - t0)
    plain_us = min(plain_lats) / BENCH_Q * 1e6
    armed_us = min(armed_lats) / BENCH_Q * 1e6
    overhead = armed_us / max(plain_us, 1e-9)
    assert overhead < 1.02, f"fault-free overhead {overhead:.4f} >= 2%"

    # -- degraded mode under injected cold-tier stalls ------------------
    # every per-bucket dispatch sleeps 30 ms; a 75 ms deadline admits
    # only the first couple of buckets, so queries return explicit
    # partial answers
    stall = FaultInjector(delays={"query.bucket": 0.03})
    armed.install_fault_injector(stall)
    degraded = 0
    partial_recalls = []
    n_queries = 12
    for _ in range(n_queries):
        res = armed.query(q, f, k=10, deadline_ms=75.0)
        if res.degraded:
            degraded += 1
            partial_recalls.append(recall(res[0], g_ref))
        else:
            assert np.array_equal(res[0], g_ref)
    counters = armed.obs.registry.snapshot()["counters"]
    armed.install_fault_injector(None)
    res_full = armed.query(q, f, k=10, deadline_ms=60_000.0)
    assert not res_full.degraded and np.array_equal(res_full[0], g_ref)

    # -- compaction crash/retry through the supervisor ------------------
    armed.delete(np.arange(0, 800))
    crash = FaultInjector(schedule={"compaction.execute": (1,)})
    armed.install_fault_injector(crash)
    armed.compact_async().join(120)
    health = armed.stats()["health"]["compactor"]
    assert health["errors"] >= 1 and health["runs"] >= 1, health
    assert not health["degraded"]
    plain.delete(np.arange(0, 800))
    plain.compact()
    g_pc, _ = plain.query(q, f, k=10)
    g_ac, _ = armed.query(q, f, k=10)
    assert np.array_equal(g_pc, g_ac)

    out = {
        "n_points": int(x.shape[0]),
        "us_per_query": round(armed_us, 1),
        "latency_samples": [{"us_per_query": round(dt / BENCH_Q * 1e6, 1)}
                            for dt in armed_lats],
        "plain_us_per_query": round(plain_us, 1),
        "fault_free_overhead_ratio": round(overhead, 4),
        "degraded_fraction": round(degraded / n_queries, 3),
        "partial_recall_at_10": (round(min(partial_recalls), 4)
                                 if partial_recalls else None),
        "degraded_queries_total": counters.get(
            "query_degraded_queries_total", 0),
        "compactor_errors": health["errors"],
        "compactor_retries": health["retries"],
        "post_crash_compaction_exact": True,
    }
    csv_row("exp17/resilience", out["us_per_query"],
            f"overhead={out['fault_free_overhead_ratio']};"
            f"degraded_frac={out['degraded_fraction']};"
            f"partial_recall={out['partial_recall_at_10']};"
            f"compactor_retries={out['compactor_retries']}")
    record("exp17_resilience", out)
    return out


if __name__ == "__main__":
    run()
