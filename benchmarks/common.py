"""Shared benchmark harness.

CPU-scaled reproduction of the paper's experiment grid: absolute QPS numbers
are container-specific; the *relative orderings and trends* are the
reproduction targets (see DESIGN.md §4).  Scale knobs via env:
REPRO_BENCH_N (default 10000), REPRO_BENCH_Q (default 32).
"""
from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict, List, Sequence

import numpy as np

BENCH_N = int(os.environ.get("REPRO_BENCH_N", 10_000))
BENCH_Q = int(os.environ.get("REPRO_BENCH_Q", 32))
BENCH_D = int(os.environ.get("REPRO_BENCH_D", 32))
RESULTS_PATH = os.path.join(os.path.dirname(__file__), "..", "experiments",
                            "bench_results.json")


def timed_queries(fn: Callable[[], np.ndarray], reps: int = 3):
    """(mean seconds per call, result of last call) with one warmup."""
    fn()                                   # warmup (jit compile)
    t0 = time.perf_counter()
    out = None
    for _ in range(reps):
        out = fn()
    dt = (time.perf_counter() - t0) / reps
    return dt, out


def qps(batch: int, seconds: float) -> float:
    return batch / max(seconds, 1e-9)


def curve(query_fn, efs: Sequence[int], queries, gt, k: int = 20,
          reps: int = 3) -> List[Dict]:
    """query_fn(ef) -> ids.  Returns [{ef, recall, qps, us_per_query}]."""
    from repro.core.workloads import recall as recall_fn
    out = []
    for ef in efs:
        dt, ids = timed_queries(lambda e=ef: query_fn(e), reps)
        out.append({"ef": ef, "recall": round(recall_fn(ids, gt), 4),
                    "qps": round(qps(len(queries), dt), 1),
                    "us_per_query": round(dt / len(queries) * 1e6, 1)})
    return out


_ALL_RESULTS: Dict[str, object] = {}


def record(section: str, payload):
    _ALL_RESULTS[section] = payload


def flush_results():
    os.makedirs(os.path.dirname(RESULTS_PATH), exist_ok=True)
    existing = {}
    if os.path.exists(RESULTS_PATH):
        try:
            existing = json.load(open(RESULTS_PATH))
        except json.JSONDecodeError:
            existing = {}
    existing.update(_ALL_RESULTS)
    with open(RESULTS_PATH, "w") as f:
        json.dump(existing, f, indent=1)
    return RESULTS_PATH


def csv_row(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")
