"""Shared benchmark harness.

CPU-scaled reproduction of the paper's experiment grid: absolute QPS numbers
are container-specific; the *relative orderings and trends* are the
reproduction targets (see DESIGN.md §4).  Scale knobs via env:
REPRO_BENCH_N (default 10000), REPRO_BENCH_Q (default 32).
"""
from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict, List, Sequence

import numpy as np

BENCH_N = int(os.environ.get("REPRO_BENCH_N", 10_000))
BENCH_Q = int(os.environ.get("REPRO_BENCH_Q", 32))
BENCH_D = int(os.environ.get("REPRO_BENCH_D", 32))
RESULTS_PATH = os.path.join(os.path.dirname(__file__), "..", "experiments",
                            "bench_results.json")


def timed_queries(fn: Callable[[], np.ndarray], reps: int = 3):
    """(mean seconds per call, result of last call) with one warmup."""
    fn()                                   # warmup (jit compile)
    t0 = time.perf_counter()
    out = None
    for _ in range(reps):
        out = fn()
    dt = (time.perf_counter() - t0) / reps
    return dt, out


def timed_query_samples(fn: Callable[[], np.ndarray], reps: int = 5):
    """(per-rep seconds list, result of last call) with one warmup.

    Use where a benchmark feeds the BENCH_streaming.json digest: the
    digest medians every ``us_per_query`` leaf it finds, so recording one
    ``{"us_per_query": ...}`` row per rep (e.g. under a
    ``latency_samples`` key) makes ``median_query_us`` a real median
    instead of a single-sample artifact (``streaming_summary`` flags
    sections whose sample count is < 3)."""
    fn()                                   # warmup (jit compile)
    samples, out = [], None
    for _ in range(max(int(reps), 1)):
        t0 = time.perf_counter()
        out = fn()
        samples.append(time.perf_counter() - t0)
    return samples, out


def qps(batch: int, seconds: float) -> float:
    return batch / max(seconds, 1e-9)


def curve(query_fn, efs: Sequence[int], queries, gt, k: int = 20,
          reps: int = 3) -> List[Dict]:
    """query_fn(ef) -> ids.  Returns [{ef, recall, qps, us_per_query}]."""
    from repro.core.workloads import recall as recall_fn
    out = []
    for ef in efs:
        dt, ids = timed_queries(lambda e=ef: query_fn(e), reps)
        out.append({"ef": ef, "recall": round(recall_fn(ids, gt), 4),
                    "qps": round(qps(len(queries), dt), 1),
                    "us_per_query": round(dt / len(queries) * 1e6, 1)})
    return out


_ALL_RESULTS: Dict[str, object] = {}


def record(section: str, payload):
    _ALL_RESULTS[section] = payload


def flush_results():
    os.makedirs(os.path.dirname(RESULTS_PATH), exist_ok=True)
    existing = {}
    if os.path.exists(RESULTS_PATH):
        try:
            existing = json.load(open(RESULTS_PATH))
        except json.JSONDecodeError:
            existing = {}
    existing.update(_ALL_RESULTS)
    with open(RESULTS_PATH, "w") as f:
        json.dump(existing, f, indent=1)
    return RESULTS_PATH


def csv_row(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")


# -- machine-readable perf trajectory (BENCH_streaming.json) -----------------
STREAMING_SECTIONS = ("exp9_", "exp10_", "exp11_", "exp12_", "exp13_",
                      "exp14_", "exp15_", "exp16_", "exp17_", "exp18_")
_SUMMARY_LATENCY_KEYS = {   # payload key -> (scale to µs, canonical name)
    "us_per_query": (1.0, "query_us"),
    "first_query_ms_after_seal": (1e3, "first_query_after_seal_us"),
    "post_compaction_first_query_ms": (1e3, "post_compaction_query_us"),
    "restored_first_query_ms": (1e3, "restored_first_query_us"),
}
_SUMMARY_BYTES_KEYS = ("pack_nbytes",)
# recall of the *production* path only — baseline keys are prefixed
# (fp32_..., rebuild_...) and sweep keys renamed, so they stay out
_SUMMARY_RECALL_KEYS = ("recall", "recall_at_10")
# dimensionless ratios reported once per section (kept as-is, not medianed).
# pruning_rate / selectivity / tracer_overhead_pct are exp-14's observed
# per-bucket aggregates — the planner-contract numbers tracked across PRs
_SUMMARY_RATIO_KEYS = ("device_bytes_ratio", "pruning_rate", "selectivity",
                       "tracer_overhead_pct")


def _collect(node, keys, out):
    """Recursively gather ``keys``-named numeric leaves from a payload."""
    if isinstance(node, dict):
        for k, v in node.items():
            if k in keys and isinstance(v, (int, float)):
                out.setdefault(k, []).append(v)
            else:
                _collect(v, keys, out)
    elif isinstance(node, (list, tuple)):
        for v in node:
            _collect(v, keys, out)


def streaming_summary(results: Dict[str, object]) -> Dict[str, dict]:
    """Compress the streaming-related sections of ``results`` into one
    machine-readable row each — a **per-metric** median (µs) for every
    latency key the section recorded, the median recall of the production
    path, peak pack bytes on device, and any device-bytes ratio — so the
    perf trajectory is diffable across PRs (``BENCH_streaming.json``).
    Medians are kept per key (steady-state ``us_per_query`` vs
    compile-laden ``first_query_ms_after_seal`` differ by orders of
    magnitude); pooling them would make the digest swing with sample
    composition rather than performance."""
    import statistics
    out: Dict[str, dict] = {}
    for section, payload in sorted(results.items()):
        if not section.startswith(STREAMING_SECTIONS):
            continue
        lat: Dict[str, list] = {}
        _collect(payload, _SUMMARY_LATENCY_KEYS, lat)
        nbytes: Dict[str, list] = {}
        _collect(payload, set(_SUMMARY_BYTES_KEYS), nbytes)
        rec: Dict[str, list] = {}
        _collect(payload, set(_SUMMARY_RECALL_KEYS), rec)
        ratios: Dict[str, list] = {}
        _collect(payload, set(_SUMMARY_RATIO_KEYS), ratios)
        row: Dict[str, object] = {}
        for key in sorted(lat):
            scale, name = _SUMMARY_LATENCY_KEYS[key]
            scaled = [v * scale for v in lat[key]]
            row[f"median_{name}"] = round(statistics.median(scaled), 1)
            row[f"n_{name}_samples"] = len(scaled)
        if rec:
            vals = [v for vs in rec.values() for v in vs]
            row["median_recall"] = round(statistics.median(vals), 4)
            row["n_recall_samples"] = len(vals)
        if nbytes:
            row["pack_nbytes"] = int(max(v for vs in nbytes.values()
                                         for v in vs))
        for key in _SUMMARY_RATIO_KEYS:
            if key in ratios:
                row[key] = max(ratios[key])
        # a median of < 3 samples is an artifact of sample composition,
        # not a statistic — name the under-sampled metrics so the digest
        # is honest about which medians to trust (satellite of exp16:
        # exp13/exp14 used to report single-sample "medians")
        low = sorted(name[2:-8] for name, v in row.items()
                     if name.startswith("n_") and name.endswith("_samples")
                     and isinstance(v, int) and v < 3)
        if low:
            row["low_sample_keys"] = low
        if row:
            out[section] = row
    return out
