"""A.6 (Fig. 13): Cube-Merge (predetermined, Alg. 3) vs Fly-Merge
(on-the-fly, Alg. 4) on identical box filters."""
from __future__ import annotations

import numpy as np

from repro.core import CubeGraphConfig, CubeGraphIndex
from repro.core.workloads import ground_truth, make_box_filter, make_dataset

from .common import BENCH_D, BENCH_N, BENCH_Q, csv_row, curve, record

K = 20


def run():
    x, s = make_dataset(BENCH_N, BENCH_D, 2, seed=23)
    rng = np.random.default_rng(24)
    q = x[rng.integers(0, BENCH_N, BENCH_Q)] \
        + 0.05 * rng.normal(size=(BENCH_Q, BENCH_D)).astype(np.float32)
    idx = CubeGraphIndex.build(x, s, CubeGraphConfig(n_layers=5, m_intra=16,
                                                     m_cross=4))
    out = {}
    for ratio in (0.05, 0.10):
        f = make_box_filter(2, ratio, seed=25)
        gt, _ = ground_truth(x, s, q, f, K)
        for mode in ("predetermined", "onthefly"):
            cu = curve(lambda ef: idx.query(q, f, k=K, ef=ef, mode=mode)[0],
                       (32, 64, 128), q, gt, K)
            out[f"{mode}_r{ratio}"] = cu
            best = max(cu, key=lambda r: r["recall"])
            csv_row(f"a6/{mode}/r{ratio}", best["us_per_query"],
                    f"recall={best['recall']};qps={best['qps']}")
    record("a6_merge_strategy", out)
    return out


if __name__ == "__main__":
    run()
