"""A.5 (Fig. 12): high-aspect-ratio rectangles — QPS degrades ~1/alpha while
recall stays high (elastic-factor decay, not graph failure)."""
from __future__ import annotations

import numpy as np

from repro.core import CubeGraphConfig, CubeGraphIndex
from repro.core.workloads import ground_truth, make_box_filter, make_dataset

from .common import BENCH_D, BENCH_N, BENCH_Q, csv_row, curve, record

K = 20


def run():
    x, s = make_dataset(BENCH_N, BENCH_D, 2, seed=20)
    rng = np.random.default_rng(21)
    q = x[rng.integers(0, BENCH_N, BENCH_Q)] \
        + 0.05 * rng.normal(size=(BENCH_Q, BENCH_D)).astype(np.float32)
    idx = CubeGraphIndex.build(x, s, CubeGraphConfig(n_layers=5, m_intra=16,
                                                     m_cross=4))
    out = {}
    for alpha in (1, 4, 16, 32):
        f = make_box_filter(2, 0.1, seed=22, aspect=float(alpha))
        gt, _ = ground_truth(x, s, q, f, K)
        cu = curve(lambda ef: idx.query(q, f, k=K, ef=ef)[0],
                   (64, 128), q, gt, K)
        out[f"alpha{alpha}"] = cu
        best = max(cu, key=lambda r: r["recall"])
        csv_row(f"a5/alpha{alpha}", best["us_per_query"],
                f"recall={best['recall']};qps={best['qps']}")
    record("a5_aspect_ratio", out)
    return out


if __name__ == "__main__":
    run()
