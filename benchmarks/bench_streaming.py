"""Exp-9 / Exp-10: streaming temporal index — lifecycle behavior under a
live write stream, and the mesh-sharded sealed-segment read path.

Exp-9 (lifecycle):
  * ingest throughput (points/s) including seal-triggered segment builds
  * time-windowed query latency + recall at checkpoints DURING ingest
  * query latency before vs after compaction (delete-heavy steady state)

Exp-10 (sharded mesh):
  * per-query latency of the sharded kernel read path on N simulated
    devices (each sealed segment split into N shards, one fused dispatch
    over segments x shards) vs the single-device scan (N=1) and the
    per-segment graph fan-out — recall against brute-force ground truth
    is reported for every path (the kernel paths are exact by design).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import (BoxFilter, ComposeFilter, CubeGraphConfig,
                        IntervalFilter)
from repro.core.workloads import ground_truth, make_dataset, recall
from repro.distributed.segment_shards import make_shard_mesh
from repro.streaming import SegmentManager, StreamConfig

from .common import BENCH_D, BENCH_N, BENCH_Q, csv_row, record, timed_queries

CFG = CubeGraphConfig(n_layers=3, m_intra=12, m_cross=4)


def _window(t_lo, t_hi):
    return ComposeFilter(
        BoxFilter(lo=np.zeros(3, np.float32), hi=np.ones(3, np.float32)),
        IntervalFilter(dim=2, lo=np.float32(t_lo), hi=np.float32(t_hi)),
        "and")


def run():
    n = max(BENCH_N, 4000)
    x, s = make_dataset(n, BENCH_D, 3, seed=21)
    s[:, 2] = np.arange(n) / n                      # event time = arrival
    rng = np.random.default_rng(22)
    q = x[rng.integers(0, n, BENCH_Q)] \
        + 0.05 * rng.normal(size=(BENCH_Q, BENCH_D)).astype(np.float32)

    mgr = SegmentManager(BENCH_D, 3, StreamConfig(
        time_dim=2, seal_max_points=max(n // 8, 512),
        compact_max_segments=4, index_cfg=CFG))

    out = {"checkpoints": []}
    chunk = max(n // 20, 256)
    checkpoints = {n // 4, n // 2, 3 * n // 4, n}
    t_ingest = 0.0
    ingested = 0
    for lo in range(0, n, chunk):
        t0 = time.perf_counter()
        mgr.ingest(x[lo:lo + chunk], s[lo:lo + chunk])
        t_ingest += time.perf_counter() - t0
        ingested = min(lo + chunk, n)
        if any(ingested >= c and ingested - chunk < c for c in checkpoints):
            # query a trailing window while the stream is live
            t_hi = ingested / n
            f = _window(max(t_hi - 0.3, 0.0), t_hi)
            dt, ids = timed_queries(lambda: mgr.query(q, f, k=10, ef=96)[0])
            gt, _ = ground_truth(x[:ingested], s[:ingested], q, f, 10,
                                 valid=mgr.alive[:ingested])
            cp = {"ingested": ingested,
                  "n_segments": len(mgr.segments),
                  "delta_live": mgr.delta.n_live,
                  "us_per_query": round(dt / BENCH_Q * 1e6, 1),
                  "recall": round(recall(ids, gt), 4)}
            out["checkpoints"].append(cp)
            csv_row(f"exp9/during_ingest_{ingested}", dt * 1e6,
                    f"recall={cp['recall']};segs={cp['n_segments']}")
    out["ingest_points_per_s"] = round(n / max(t_ingest, 1e-9), 1)
    csv_row("exp9/ingest_throughput", t_ingest * 1e6 / n,
            f"points_per_s={out['ingest_points_per_s']}")

    # -- steady state: heavy deletions, then compaction ---------------------
    dead = rng.choice(n // 2, size=n // 4, replace=False)
    mgr.delete(dead)
    f = _window(0.0, 1.0)
    dt_pre, ids_pre = timed_queries(lambda: mgr.query(q, f, k=10, ef=96)[0])
    gt, _ = ground_truth(x, s, q, f, 10, valid=mgr.alive)
    r_pre = recall(ids_pre, gt)
    n_segs_pre = len(mgr.segments)

    t0 = time.perf_counter()
    ops = mgr.compact()
    t_compact = time.perf_counter() - t0
    dt_post, ids_post = timed_queries(lambda: mgr.query(q, f, k=10, ef=96)[0])
    r_post = recall(ids_post, gt)

    out["before_compaction"] = {"us_per_query": round(dt_pre / BENCH_Q * 1e6, 1),
                                "recall": round(r_pre, 4),
                                "n_segments": n_segs_pre}
    out["compaction"] = {"ops": ops, "seconds": round(t_compact, 2),
                         "n_segments_after": len(mgr.segments)}
    out["after_compaction"] = {"us_per_query": round(dt_post / BENCH_Q * 1e6, 1),
                               "recall": round(r_post, 4)}
    csv_row("exp9/query_before_compaction", dt_pre * 1e6,
            f"recall={r_pre:.3f}")
    csv_row("exp9/compaction", t_compact * 1e6, f"ops={ops}")
    csv_row("exp9/query_after_compaction", dt_post * 1e6,
            f"recall={r_post:.3f};"
            f"speedup={dt_pre / max(dt_post, 1e-9):.2f}x")
    record("exp9_streaming", out)
    return out


def run_sharded():
    """Exp-10: sharded sealed-segment search over a (simulated) device mesh."""
    n = max(BENCH_N, 8000)
    d = BENCH_D
    x, s = make_dataset(n, d, 3, seed=31)
    s[:, 2] = np.arange(n) / n
    rng = np.random.default_rng(32)
    q = x[rng.integers(0, n, BENCH_Q)] \
        + 0.05 * rng.normal(size=(BENCH_Q, d)).astype(np.float32)
    f = _window(0.25, 0.9)
    gt, _ = ground_truth(x, s, q, f, 10)
    mesh = make_shard_mesh()

    out = {"n_points": n, "mesh_devices": int(mesh.devices.size),
           "note": ("1 real device on this container; each shard is one "
                    "simulated device of the mesh"),
           "paths": []}

    def one_path(label, n_shards, **query_kw):
        mgr = SegmentManager(d, 3, StreamConfig(
            time_dim=2, seal_max_points=2048, n_shards=n_shards,
            index_cfg=CFG), shard_mesh=mesh)
        mgr.ingest(x, s)
        dt, ids = timed_queries(
            lambda: mgr.query(q, f, k=10, **query_kw)[0], reps=5)
        row = {"path": label, "n_shards": n_shards,
               "us_per_query": round(dt / BENCH_Q * 1e6, 1),
               "recall": round(recall(ids, gt), 4)}
        out["paths"].append(row)
        csv_row(f"exp10/{label}", dt * 1e6,
                f"recall={row['recall']};us_per_query={row['us_per_query']}")
        return row

    one_path("graph_fanout", 0, ef=96)
    base = one_path("sharded_1dev", 1)
    for ns in (2, 4, 8):
        row = one_path(f"sharded_{ns}dev", ns)
        row["vs_single_device"] = round(
            base["us_per_query"] / max(row["us_per_query"], 1e-9), 3)
    record("exp10_sharded_mesh", out)
    return out


if __name__ == "__main__":
    run()
