"""Exp-9 / Exp-10 / Exp-12: streaming temporal index — lifecycle behavior
under a live write stream, the mesh-sharded sealed-segment read path, and
shard-pack maintenance cost.

Exp-9 (lifecycle):
  * ingest throughput (points/s) including seal-triggered segment builds
  * time-windowed query latency + recall at checkpoints DURING ingest
  * query latency before vs after compaction (delete-heavy steady state)

Exp-10 (sharded mesh):
  * per-query latency of the sharded kernel read path on N simulated
    devices (each sealed segment split into N shards, one fused dispatch
    over segments x shards) vs the single-device scan (N=1) and the
    per-segment graph fan-out — recall against brute-force ground truth
    is reported for every path (the kernel paths are exact by design).

Exp-12 (pack maintenance):
  * first-query latency immediately after each seal and after a
    compaction publish — the legacy full-rebuild pack (every epoch bump
    re-stacks and re-uploads every segment) vs the size-bucketed
    incrementally maintained pack (O(changed-segments) deltas)
  * pack bytes-on-device under segment-count skew (one jumbo + many small
    segments): the monolithic layout pads every shard to the jumbo's
    capacity, the bucketed layout pads per capacity class
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import (BoxFilter, ComposeFilter, CubeGraphConfig,
                        IntervalFilter)
from repro.core.workloads import ground_truth, make_dataset, recall
from repro.distributed.segment_shards import make_shard_mesh
from repro.streaming import SegmentManager, StreamConfig

from .common import BENCH_D, BENCH_N, BENCH_Q, csv_row, record, timed_queries

CFG = CubeGraphConfig(n_layers=3, m_intra=12, m_cross=4)


def _window(t_lo, t_hi):
    return ComposeFilter(
        BoxFilter(lo=np.zeros(3, np.float32), hi=np.ones(3, np.float32)),
        IntervalFilter(dim=2, lo=np.float32(t_lo), hi=np.float32(t_hi)),
        "and")


def run():
    n = max(BENCH_N, 4000)
    x, s = make_dataset(n, BENCH_D, 3, seed=21)
    s[:, 2] = np.arange(n) / n                      # event time = arrival
    rng = np.random.default_rng(22)
    q = x[rng.integers(0, n, BENCH_Q)] \
        + 0.05 * rng.normal(size=(BENCH_Q, BENCH_D)).astype(np.float32)

    mgr = SegmentManager(BENCH_D, 3, StreamConfig(
        time_dim=2, seal_max_points=max(n // 8, 512),
        compact_max_segments=4, index_cfg=CFG))

    out = {"checkpoints": []}
    chunk = max(n // 20, 256)
    checkpoints = {n // 4, n // 2, 3 * n // 4, n}
    t_ingest = 0.0
    ingested = 0
    for lo in range(0, n, chunk):
        t0 = time.perf_counter()
        mgr.ingest(x[lo:lo + chunk], s[lo:lo + chunk])
        t_ingest += time.perf_counter() - t0
        ingested = min(lo + chunk, n)
        if any(ingested >= c and ingested - chunk < c for c in checkpoints):
            # query a trailing window while the stream is live
            t_hi = ingested / n
            f = _window(max(t_hi - 0.3, 0.0), t_hi)
            dt, ids = timed_queries(lambda: mgr.query(q, f, k=10, ef=96)[0])
            gt, _ = ground_truth(x[:ingested], s[:ingested], q, f, 10,
                                 valid=mgr.alive[:ingested])
            cp = {"ingested": ingested,
                  "n_segments": len(mgr.segments),
                  "delta_live": mgr.delta.n_live,
                  "us_per_query": round(dt / BENCH_Q * 1e6, 1),
                  "recall": round(recall(ids, gt), 4)}
            out["checkpoints"].append(cp)
            csv_row(f"exp9/during_ingest_{ingested}", dt * 1e6,
                    f"recall={cp['recall']};segs={cp['n_segments']}")
    out["ingest_points_per_s"] = round(n / max(t_ingest, 1e-9), 1)
    csv_row("exp9/ingest_throughput", t_ingest * 1e6 / n,
            f"points_per_s={out['ingest_points_per_s']}")

    # -- steady state: heavy deletions, then compaction ---------------------
    dead = rng.choice(n // 2, size=n // 4, replace=False)
    mgr.delete(dead)
    f = _window(0.0, 1.0)
    dt_pre, ids_pre = timed_queries(lambda: mgr.query(q, f, k=10, ef=96)[0])
    gt, _ = ground_truth(x, s, q, f, 10, valid=mgr.alive)
    r_pre = recall(ids_pre, gt)
    n_segs_pre = len(mgr.segments)

    t0 = time.perf_counter()
    ops = mgr.compact()
    t_compact = time.perf_counter() - t0
    dt_post, ids_post = timed_queries(lambda: mgr.query(q, f, k=10, ef=96)[0])
    r_post = recall(ids_post, gt)

    out["before_compaction"] = {"us_per_query": round(dt_pre / BENCH_Q * 1e6, 1),
                                "recall": round(r_pre, 4),
                                "n_segments": n_segs_pre}
    out["compaction"] = {"ops": ops, "seconds": round(t_compact, 2),
                         "n_segments_after": len(mgr.segments)}
    out["after_compaction"] = {"us_per_query": round(dt_post / BENCH_Q * 1e6, 1),
                               "recall": round(r_post, 4)}
    csv_row("exp9/query_before_compaction", dt_pre * 1e6,
            f"recall={r_pre:.3f}")
    csv_row("exp9/compaction", t_compact * 1e6, f"ops={ops}")
    csv_row("exp9/query_after_compaction", dt_post * 1e6,
            f"recall={r_post:.3f};"
            f"speedup={dt_pre / max(dt_post, 1e-9):.2f}x")
    record("exp9_streaming", out)
    return out


def run_sharded():
    """Exp-10: sharded sealed-segment search over a (simulated) device mesh."""
    n = max(BENCH_N, 8000)
    d = BENCH_D
    x, s = make_dataset(n, d, 3, seed=31)
    s[:, 2] = np.arange(n) / n
    rng = np.random.default_rng(32)
    q = x[rng.integers(0, n, BENCH_Q)] \
        + 0.05 * rng.normal(size=(BENCH_Q, d)).astype(np.float32)
    f = _window(0.25, 0.9)
    gt, _ = ground_truth(x, s, q, f, 10)
    mesh = make_shard_mesh()

    out = {"n_points": n, "mesh_devices": int(mesh.devices.size),
           "note": ("1 real device on this container; each shard is one "
                    "simulated device of the mesh"),
           "paths": []}

    def one_path(label, n_shards, **query_kw):
        mgr = SegmentManager(d, 3, StreamConfig(
            time_dim=2, seal_max_points=2048, n_shards=n_shards,
            index_cfg=CFG), shard_mesh=mesh)
        mgr.ingest(x, s)
        dt, ids = timed_queries(
            lambda: mgr.query(q, f, k=10, **query_kw)[0], reps=5)
        # the graph fan-out is a different algorithm, not the sharded
        # production path: keep its latency AND recall out of the
        # BENCH_streaming.json digest (same convention as exp12's
        # "rebuild_" / exp13's "fp32_" baseline prefixes)
        prod = n_shards >= 1
        key = "us_per_query" if prod else "graph_us_per_query"
        rkey = "recall" if prod else "graph_recall"
        row = {"path": label, "n_shards": n_shards,
               key: round(dt / BENCH_Q * 1e6, 1),
               rkey: round(recall(ids, gt), 4)}
        out["paths"].append(row)
        csv_row(f"exp10/{label}", dt * 1e6,
                f"recall={row[rkey]};us_per_query={row[key]}")
        return row

    one_path("graph_fanout", 0, ef=96)
    base = one_path("sharded_1dev", 1)
    for ns in (2, 4, 8):
        row = one_path(f"sharded_{ns}dev", ns)
        row["vs_single_device"] = round(
            base["us_per_query"] / max(row["us_per_query"], 1e-9), 3)
    record("exp10_sharded_mesh", out)
    return out


def run_pack_maintenance():
    """Exp-12: post-seal/post-compaction first-query latency and device
    bytes — legacy full-rebuild pack vs size-bucketed incremental pack."""
    d = BENCH_D
    jumbo = max(BENCH_N // 2, 2048)      # one post-compaction-sized segment
    small = max(BENCH_N // 24, 256)      # ... plus a stream of small seals
    n_small = 10
    rng = np.random.default_rng(41)
    q = rng.normal(size=(BENCH_Q, d)).astype(np.float32)

    def batch(gen, n, t0):
        x = gen.normal(size=(n, d)).astype(np.float32)
        s = gen.uniform(size=(n, 3))
        s[:, 2] = t0 + np.linspace(0.0, 0.9, n)
        return x, s

    out = {"jumbo_points": jumbo, "small_points": small,
           "n_small_segments": n_small, "modes": {}}
    # the legacy baseline's keys are "rebuild_"-prefixed so the perf
    # trajectory (BENCH_streaming.json) summarizes only the production
    # bucketed-incremental path
    for mode, incremental in (("full_rebuild", False),
                              ("bucketed_incremental", True)):
        tag = "" if incremental else "rebuild_"
        gen = np.random.default_rng(41)          # identical streams
        mgr = SegmentManager(d, 3, StreamConfig(
            time_dim=2, seal_max_points=1 << 30, n_shards=2,
            incremental_pack=incremental, index_cfg=CFG))
        x, s = batch(gen, jumbo, 0.0)            # the jumbo segment first
        mgr.ingest(x, s)
        mgr.seal()
        mgr.query(q, None, k=10)                 # build + compile once
        lats, series = [], []
        for i in range(n_small):
            x, s = batch(gen, small, float(i + 1))
            mgr.ingest(x, s)
            mgr.seal()
            t0 = time.perf_counter()             # first query after seal
            mgr.query(q, None, k=10)
            lat_ms = (time.perf_counter() - t0) * 1e3
            lats.append(lat_ms)
            series.append({
                "n_segments": len(mgr.segments),
                tag + "first_query_ms_after_seal": round(lat_ms, 2)})
        lats.sort()
        # compaction publish: GC-rewrite one heavily deleted small segment
        victim = mgr.segments[-1]
        mgr.delete(victim.gids[: int(0.6 * len(victim.gids))])
        mgr.compact()
        t0 = time.perf_counter()
        mgr.query(q, None, k=10)
        post_compact_ms = (time.perf_counter() - t0) * 1e3
        st = mgr.stats()
        row = {
            tag + "p50_first_query_ms": round(lats[len(lats) // 2], 2),
            tag + "p99_first_query_ms": round(lats[min(len(lats) - 1, int(
                np.ceil(0.99 * len(lats)) - 1))], 2),
            tag + "post_compaction_first_query_ms": round(post_compact_ms, 2),
            tag + "pack_nbytes": st["pack_nbytes"],
            "pack_buckets": {str(cap): v
                             for cap, v in st["pack_buckets"].items()},
            "series": series,
        }
        out["modes"][mode] = row
        csv_row(f"exp12/{mode}", row[tag + "p99_first_query_ms"] * 1e3,
                f"p50_ms={row[tag + 'p50_first_query_ms']};"
                f"post_compact_ms="
                f"{row[tag + 'post_compaction_first_query_ms']};"
                f"pack_nbytes={row[tag + 'pack_nbytes']}")
    fr = out["modes"]["full_rebuild"]
    bi = out["modes"]["bucketed_incremental"]
    out["p99_speedup"] = round(fr["rebuild_p99_first_query_ms"]
                               / max(bi["p99_first_query_ms"], 1e-9), 2)
    out["pack_bytes_ratio"] = round(
        fr["rebuild_pack_nbytes"] / max(bi["pack_nbytes"], 1), 2)
    csv_row("exp12/summary", 0.0,
            f"p99_speedup={out['p99_speedup']}x;"
            f"pack_bytes_ratio={out['pack_bytes_ratio']}x")
    record("exp12_pack_maintenance", out)
    return out


if __name__ == "__main__":
    run()
