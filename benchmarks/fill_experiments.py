"""Inject the generated roofline + perf tables into EXPERIMENTS.md
(replaces the <!-- ROOFLINE_TABLE --> / <!-- PERF_TABLE --> markers)."""
from __future__ import annotations

import glob
import io
import json
import os
import sys
from contextlib import redirect_stdout

HERE = os.path.dirname(__file__)
EXP = os.path.join(HERE, "..", "EXPERIMENTS.md")
PERF_DIR = os.path.join(HERE, "..", "experiments", "perf")


def roofline_markdown() -> str:
    from .roofline import load_cells, fmt_row, HEADER
    cells = load_cells("pod1")
    buf = ["| " + " | ".join(HEADER) + " |", "|" + "---|" * len(HEADER)]
    for r in cells:
        buf.append("| " + " | ".join(str(c) for c in fmt_row(r)) + " |")
    ok = sum(1 for r in cells if r["status"] == "ok")
    skip = sum(1 for r in cells if r["status"] == "skipped")
    buf.append("")
    buf.append(f"*{len(cells)} pod1 cells: {ok} ok, {skip} skipped, "
               f"{len(cells)-ok-skip} error.  pod2 (512-chip) compile+memory "
               "evidence in `experiments/dryrun/*__pod2.json`.*")
    return "\n".join(buf)


def perf_markdown() -> str:
    rows = ["| cell | variant | peak GB | fits | compute_s | memory_s | "
            "collective_s | bottleneck | useful |",
            "|---|---|---|---|---|---|---|---|---|"]
    for path in sorted(glob.glob(os.path.join(PERF_DIR, "*.json"))):
        r = json.load(open(path))
        m = r["full"]["memory"]
        ro = r.get("roofline", {})
        rows.append(
            f"| {r['arch']}/{r['shape']}/{r['mesh']} | {r['variant']}"
            f"{'+accum'+str(r['accum']) if r.get('accum',1)>1 else ''} | "
            f"{m['peak_per_device_bytes']/1e9:.1f} | "
            f"{'Y' if m['fits_hbm'] else 'N'} | "
            f"{ro.get('compute_s', float('nan')):.3f} | "
            f"{ro.get('memory_s', float('nan')):.3f} | "
            f"{ro.get('collective_s', float('nan')):.3f} | "
            f"{ro.get('bottleneck','-')} | {ro.get('useful_ratio',0):.2f} |")
    if len(rows) == 2:
        return "*(no perf variants recorded yet)*"
    return "\n".join(rows)


def main():
    src = open(EXP).read()
    src = src.replace("<!-- ROOFLINE_TABLE -->", roofline_markdown())
    src = src.replace("<!-- PERF_TABLE -->", perf_markdown())
    open(EXP, "w").write(src)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
