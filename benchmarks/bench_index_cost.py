"""Exp-4 (Tables 5/6): index construction time and size."""
from __future__ import annotations

from repro.core import CubeGraphConfig, CubeGraphIndex
from repro.core.baselines import (AcornIndex, PostFilteringIndex,
                                  TreeGraphIndex)
from repro.core.workloads import make_dataset

from .common import BENCH_D, BENCH_N, csv_row, record


def run():
    x, s = make_dataset(BENCH_N, BENCH_D, 2, seed=8)
    out = {}
    builders = {
        "cubegraph": lambda: CubeGraphIndex.build(
            x, s, CubeGraphConfig(n_layers=5, m_intra=16, m_cross=4)),
        "postfilter(hnsw-like)": lambda: PostFilteringIndex(x, s, m_intra=16),
        "acorn-g12": lambda: AcornIndex(x, s, m_intra=16, gamma=12),
        "treegraph": lambda: TreeGraphIndex(
            x, s, leaf_size=max(BENCH_N // 32, 128), m_intra=16),
    }
    vector_mb = x.size * 4 / 1e6
    for name, build in builders.items():
        idx = build()
        secs = idx.build_seconds
        mb = idx.index_bytes() / 1e6
        out[name] = {"build_s": round(secs, 2), "index_MB": round(mb, 2),
                     "vector_MB": round(vector_mb, 2)}
        csv_row(f"exp4/{name}", secs * 1e6,
                f"build_s={secs:.1f};index_MB={mb:.1f}")
    record("exp4_index_cost", out)
    return out


if __name__ == "__main__":
    run()
