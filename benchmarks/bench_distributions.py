"""Exp-8 (Fig. 11): metadata distributions — uniform / normal / clustered /
skewed / hollow."""
from __future__ import annotations

import numpy as np

from repro.core import CubeGraphConfig, CubeGraphIndex
from repro.core.workloads import ground_truth, make_box_filter, make_dataset

from .common import BENCH_D, BENCH_N, BENCH_Q, csv_row, curve, record

K = 20


def run():
    out = {}
    rng = np.random.default_rng(17)
    for dist in ("uniform", "normal", "clustered", "skewed", "hollow"):
        x, s = make_dataset(BENCH_N, BENCH_D, 2, distribution=dist, seed=18)
        q = x[rng.integers(0, BENCH_N, BENCH_Q)] \
            + 0.05 * rng.normal(size=(BENCH_Q, BENCH_D)).astype(np.float32)
        idx = CubeGraphIndex.build(x, s, CubeGraphConfig(
            n_layers=5, m_intra=16, m_cross=4))
        for ratio in (0.05, 0.10):
            f = make_box_filter(2, ratio, seed=19 + int(ratio * 100))
            gt, _ = ground_truth(x, s, q, f, K)
            cu = curve(lambda ef: idx.query(q, f, k=K, ef=ef)[0],
                       (32, 64, 128), q, gt, K)
            out[f"{dist}_r{ratio}"] = cu
            best = max(cu, key=lambda r: r["recall"])
            csv_row(f"exp8/{dist}/r{ratio}", best["us_per_query"],
                    f"recall={best['recall']};qps={best['qps']}")
    record("exp8_distributions", out)
    return out


if __name__ == "__main__":
    run()
