"""Kernel microbenchmarks: fused filtered-topk Pallas kernel vs unfused jnp
reference (interpret mode on CPU — wall times indicative only; the BlockSpec
tiling targets TPU VMEM)."""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core.workloads import make_box_filter, make_dataset
from repro.kernels import filtered_topk
from repro.kernels.ref import filtered_topk_ref
from repro.kernels.ops import encode_filter

from .common import csv_row, record


def run():
    out = {}
    for (bq, n, d) in ((32, 4096, 128), (64, 8192, 64)):
        x, s = make_dataset(n, d, 2, seed=26)
        q = x[:bq] + 0.01
        f = make_box_filter(2, 0.1, seed=27)
        kind, params = encode_filter(f, 2)

        def kern():
            ids, dd = filtered_topk(q, x, s, f, 10)
            return np.asarray(ids)

        def ref():
            dd, ids = filtered_topk_ref(q, x, s, kind, params, 10)
            return np.asarray(ids)

        for name, fn in (("pallas_interp", kern), ("jnp_ref", ref)):
            fn()
            t0 = time.perf_counter()
            for _ in range(3):
                r = fn()
            dt = (time.perf_counter() - t0) / 3
            out[f"{name}_b{bq}_n{n}_d{d}"] = round(dt * 1e6, 1)
            csv_row(f"kernels/{name}/b{bq}n{n}d{d}", dt * 1e6,
                    f"us={dt*1e6:.0f}")
    record("kernel_microbench", out)
    return out


if __name__ == "__main__":
    run()
