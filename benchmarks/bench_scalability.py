"""Exp-7 (Fig. 10): scalability — build time and query efficiency vs N."""
from __future__ import annotations

import numpy as np

from repro.core import CubeGraphConfig, CubeGraphIndex
from repro.core.workloads import ground_truth, make_box_filter, make_dataset

from .common import BENCH_D, BENCH_N, BENCH_Q, csv_row, curve, record

K = 20


def run():
    out = {}
    rng = np.random.default_rng(15)
    for n in (BENCH_N // 4, BENCH_N // 2, BENCH_N, BENCH_N * 2):
        x, s = make_dataset(n, BENCH_D, 2, seed=n)
        q = x[rng.integers(0, n, BENCH_Q)] \
            + 0.05 * rng.normal(size=(BENCH_Q, BENCH_D)).astype(np.float32)
        idx = CubeGraphIndex.build(x, s, CubeGraphConfig(
            n_layers=5, m_intra=16, m_cross=4))
        f = make_box_filter(2, 0.05, seed=16)
        gt, _ = ground_truth(x, s, q, f, K)
        cu = curve(lambda ef: idx.query(q, f, k=K, ef=ef)[0],
                   (64, 128), q, gt, K)
        best = max(cu, key=lambda r: r["recall"])
        out[f"n{n}"] = {"build_s": round(idx.build_seconds, 2), "curve": cu}
        csv_row(f"exp7/n{n}", best["us_per_query"],
                f"recall={best['recall']};qps={best['qps']};"
                f"build_s={idx.build_seconds:.1f}")
    record("exp7_scalability", out)
    return out


if __name__ == "__main__":
    run()
